"""Public ops for block-sparse linears: jit'd, differentiable, backend-dispatched.

``bsr_linear(x, data, pack)`` is the layer-facing op: custom_vjp over both the
activations and the stored tile values so sparse *training* works (gradient of
pruned blocks is exactly zero -- they stay dead).

Backends:
  * "pallas"  -- the TPU kernels of bsr_matmul.py (interpret=True off-TPU,
                 which is far too slow to serve from: CPU uses rowpack);
  * "rowpack" -- row-grouped batched matmul, the measured CPU fast path and
                 the off-TPU default (TVM+ analogue in benchmarks/table1).
                 Its static layout (fixed P = max tiles/row) is computed once
                 per pattern and cached; because ``data`` arrives in the
                 packed (nnzt, bn, bk) layout, this backend still pays one
                 scatter-to-row-groups per call;
  * "gather"  -- pure-XLA sparse path (ref.bsr_matmul_gather): one gather per
                 stored tile, O(nnzt) scattered traffic -- simple, and the
                 baseline rowpack overtook (docs/PERF.md);
  * "ref"     -- densify oracle (correctness reference, not a serving path).

``default_backend()`` picks pallas on TPU, rowpack elsewhere.

The serving-optimal path is NOT a ``bsr_linear`` backend: store weights
row-grouped offline and call ``exec_plan.plan_linear`` directly (what the
repro.serving exports do). That removes the per-call scatter too --
see docs/PERF.md for the measured ladder gather -> rowpack -> plan.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsr import BSR
from repro.kernels import bsr_matmul as bk
from repro.kernels import exec_plan as xp
from repro.kernels import ref as kref
from repro.kernels.bsr_matmul import KernelBSR, pack_bsr


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "rowpack"


def _rowpack_layout(pack: KernelBSR):
    """Static row-grouped layout (col_idx (R, P), slot (nnzt,), P) with the
    seed semantics: fixed P = max tiles/row, padding tiles included.

    Vectorized (the seed rebuilt this with a Python loop at every trace) and
    cached per pattern fingerprint through the plan registry; the adaptive
    spill-scheduled layout lives in exec_plan.build_plan -- this fixed
    layout is kept as the measured baseline the plan path is benchmarked
    against (docs/PERF.md).
    """
    def build():
        rows = np.asarray(pack.row_id[: pack.nnzt], dtype=np.int64)
        r = pack.n_brows
        counts = np.bincount(rows, minlength=r)
        p = max(1, int(counts.max()))
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        order = np.argsort(rows, kind="stable")
        slot = np.empty(rows.shape[0], np.int64)
        slot[order] = np.arange(rows.shape[0]) - starts[rows[order]]
        col_idx = np.zeros((r, p), np.int64)
        col_idx[rows, slot] = pack.col_id
        return col_idx, slot, p

    reg = xp.default_plan_registry()
    key = ("rowpack_layout", xp.kernel_pattern_fingerprint(pack))
    return reg.cached(key, build)


def _rowpack_matmul(x, data, pack: KernelBSR):
    """Row-grouped matmul (docs/PERF.md §rowpack): instead of one gather per
    stored block (O(M * nnzt * bk) scattered traffic), group blocks by output
    row, pad to P = max blocks/row, and run ONE batched
    (R, M, P*bk) x (R, P*bk, bn) matmul. Padding blocks multiply zeros.

    The data re-layout below runs on every call because this backend's ABI
    takes ``data`` in the packed (nnzt, bn, bk) layout -- exactly the cost
    the RowPackPlan serving path moves offline.
    """
    m = x.shape[0]
    n, k = pack.shape
    bn, bk = pack.tile
    r = pack.n_brows
    col_idx, slot, p = _rowpack_layout(pack)
    rows = pack.row_id[: pack.nnzt]
    data_rp = jnp.zeros((r, p, bn, bk), data.dtype)
    data_rp = data_rp.at[jnp.asarray(rows), jnp.asarray(slot)].set(data)
    xg = x.reshape(m, k // bk, bk)[:, jnp.asarray(col_idx)]   # (M,R,P,bk)
    y = jnp.einsum("mrpk,rpnk->rmn", xg, data_rp,
                   preferred_element_type=jnp.float32)        # (R,M,bn)
    return y.transpose(1, 0, 2).reshape(m, n).astype(x.dtype)


def _core_bsr_from_pack(data, pack: KernelBSR) -> BSR:
    """View a KernelBSR (static pattern) as a core BSR (for the gather path)."""
    nbr = pack.n_brows
    counts = np.bincount(pack.row_id[:-1], minlength=nbr)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return BSR(data, jnp.asarray(pack.col_id), jnp.asarray(indptr),
               pack.shape, pack.tile)


# --------------------------------------------------------------------------
# differentiable primitive
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def bsr_linear(x, data, pack: KernelBSR, backend: str = "gather"):
    """Y(M, N) = X(M, K) @ W^T with W = (pack pattern, data values)."""
    return _bsr_linear_fwd_impl(x, data, pack, backend)


def _bsr_linear_fwd_impl(x, data, pack, backend):
    if backend == "pallas":
        return bk.dds(x, _with_data(pack, data),
                      interpret=jax.default_backend() != "tpu")
    if backend == "rowpack":
        return _rowpack_matmul(x, data, pack)
    m = _core_bsr_from_pack(data, pack)
    if backend == "gather":
        return kref.bsr_matmul_gather(x, m)
    if backend == "ref":
        return kref.bsr_matmul_ref(x, m)
    raise ValueError(f"unknown backend {backend}")


def _bsr_linear_fwd(x, data, pack, backend):
    return _bsr_linear_fwd_impl(x, data, pack, backend), (x, data)


def _bsr_linear_bwd(pack, backend, res, dy):
    x, data = res
    interp = jax.default_backend() != "tpu"
    if backend == "pallas":
        dx = bk.dds_t(dy, _with_data(pack, data), interpret=interp)
        ddata = bk.sddmm(dy, x, _with_data(pack, data), interpret=interp)
    else:
        m = _core_bsr_from_pack(data, pack)
        dx = kref.bsr_matmul_t_gather(dy, m)
        ddata = kref.sddmm_ref(dy, x, m)
        ddata = ddata * jnp.asarray(pack.pad_mask())[:, None, None].astype(ddata.dtype)
    return dx.astype(x.dtype), ddata.astype(data.dtype)


bsr_linear.defvjp(_bsr_linear_fwd, _bsr_linear_bwd)


def _with_data(pack: KernelBSR, data) -> KernelBSR:
    return KernelBSR(data, pack.row_id, pack.col_id, pack.t_perm,
                     pack.real_nnzt, pack.shape, pack.tile)


# --------------------------------------------------------------------------
# convenience wrappers
# --------------------------------------------------------------------------

def bsr_matmul(x: jax.Array, w: KernelBSR, backend: str | None = None):
    """Batched-x entry point: x (..., K) -> (..., N)."""
    backend = backend or default_backend()
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = bsr_linear(x2, w.data, w, backend)
    return y.reshape(*lead, w.shape[0])


def default_plan_backend() -> str:
    """Execution backend for row-grouped plan layouts: the compiled
    plan-consuming Pallas kernel on TPU, the XLA composition elsewhere."""
    return "plan_pallas" if jax.default_backend() == "tpu" else "plan"


def plan_dispatch(x, data_rp, plan, backend: str | None = None):
    """Plan-layout matmul behind a backend switch: 'plan' = the XLA
    gather/einsum/segment-sum composition (exec_plan.plan_matmul),
    'plan_pallas' = the compiled kernel driven by the plan's spill schedule
    (exec_plan.plan_matmul_pallas). Both differentiate; both take the same
    row-grouped (V, P, bn, bk) values."""
    backend = backend or default_plan_backend()
    if backend == "plan_pallas":
        return xp.plan_matmul_pallas(x, data_rp, plan)
    if backend == "plan":
        return xp.plan_matmul(x, data_rp, plan)
    raise ValueError(f"unknown plan backend {backend}")


def plan_q_dispatch(x, qvalues, scales, plan, backend: str | None = None):
    """Quantized-pack matmul behind the same backend switch: 'plan' = the
    dequant-fused XLA composition (exec_plan.plan_q_matmul), 'plan_pallas'
    = the compiled kernel with the scale multiply in the accumulation
    (exec_plan.plan_q_matmul_pallas)."""
    backend = backend or default_plan_backend()
    if backend == "plan_pallas":
        return xp.plan_q_matmul_pallas(x, qvalues, scales, plan)
    if backend == "plan":
        return xp.plan_q_matmul(x, qvalues, scales, plan)
    raise ValueError(f"unknown plan backend {backend}")


def sparsify_weight(w_dense, tile: Tuple[int, int] = (128, 128),
                    nnzt: int | None = None) -> KernelBSR:
    """Host-side packing step (offline, like TVM's relay BSR conversion)."""
    return pack_bsr(np.asarray(jax.device_get(w_dense)), tile, nnzt)
