"""Async, atomic, elastic checkpointing (orbax-free, offline-safe).

Layout: <dir>/step_<N>/  shard files `arrays.npz` (host-local full values) +
`meta.json`. Writes go to `step_<N>.tmp` then atomically rename -- a crashed
writer never corrupts the latest checkpoint. A background thread does the
serialization so the train loop only pays for the device->host copy.

Elastic restore: arrays are saved unsharded (host canonical); on load they
are placed onto whatever mesh/sharding the *new* topology dictates -- so a
job can restart on a different device count (scale up/down) and keep going.
At real multi-pod scale the same protocol applies per-host with a sharded
file set; the single-process container collapses hosts to one (DESIGN.md).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- write ------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False,
             extra: Optional[dict] = None):
        """Device->host copy now; serialization in background."""
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        self.wait()   # one in-flight save at a time
        self._thread = threading.Thread(
            target=self._write, args=(step, host_leaves, extra or {}),
            daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host_leaves, extra):
        tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
        final = os.path.join(self.dir, f"step_{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": l for i, l in enumerate(host_leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_arrays": len(host_leaves),
                       "time": time.time(), **extra}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- read -------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally placing each
        leaf with the given shardings tree (elastic re-shard)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}", "arrays.npz")
        data = np.load(path)
        leaves, treedef = _flatten(like)
        new_leaves = []
        for i, ref in enumerate(leaves):
            arr = data[f"a{i}"]
            if hasattr(ref, "dtype"):
                arr = arr.astype(ref.dtype)
            new_leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree

    def meta(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:09d}", "meta.json")) as f:
            return json.load(f)
