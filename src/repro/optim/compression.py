"""Block-sparse gradient compression with error feedback (DP all-reduce path).

Distributed-optimization trick tied to the paper's theme: gradients are
compressed to the top-K *blocks* per tensor (the same block-magnitude
machinery as core.sparsity) before the data-parallel exchange; the residual
accumulates in an error-feedback buffer (Deep-Gradient-Compression style) so
convergence is preserved.

Wire format per tensor: (values (K, bh, bw), flat block indices (K,)). The
collective becomes an all-gather of K*bh*bw + K elements per peer instead of
an all-reduce of the full tensor -- at 1-5 % density this is a >10x byte
reduction on the DP axis, visible in the dry-run HLO as all-gathers of small
operands. Used inside shard_map over the DP axes (launch/train.py, flag
``grad_compression``); FSDP-sharded dims stay uncompressed (scope note in
DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    block_shape: Tuple[int, int] = (8, 128)   # lane-aligned wire blocks
    density: float = 0.05                     # fraction of blocks kept
    min_size: int = 65536                     # don't compress small leaves


def _blockify(g, bs):
    bh, bw = bs
    r, c = g.shape
    return g.reshape(r // bh, bh, c // bw, bw).transpose(0, 2, 1, 3).reshape(
        -1, bh, bw)


def _unblockify(blocks, shape, bs):
    bh, bw = bs
    r, c = shape
    return blocks.reshape(r // bh, c // bw, bh, bw).transpose(0, 2, 1, 3
                                                              ).reshape(r, c)


def compressible(leaf, cfg: CompressionConfig) -> bool:
    bh, bw = cfg.block_shape
    return (leaf.ndim == 2 and leaf.size >= cfg.min_size
            and leaf.shape[0] % bh == 0 and leaf.shape[1] % bw == 0)


def compress(g, err, cfg: CompressionConfig):
    """(grad, error buffer) -> (values, indices, new_error)."""
    acc = g.astype(jnp.float32) + err
    blocks = _blockify(acc, cfg.block_shape)              # (NB, bh, bw)
    nb = blocks.shape[0]
    k = max(1, int(nb * cfg.density))
    norms = jnp.sum(blocks * blocks, axis=(1, 2))
    _, idx = jax.lax.top_k(norms, k)                      # (K,)
    vals = blocks[idx]                                    # (K, bh, bw)
    kept = jnp.zeros((nb,), bool).at[idx].set(True)
    new_err = _unblockify(jnp.where(kept[:, None, None], 0.0, blocks),
                          acc.shape, cfg.block_shape)
    return vals, idx.astype(jnp.int32), new_err


def decompress(vals, idx, shape, cfg: CompressionConfig):
    bh, bw = cfg.block_shape
    nb = (shape[0] // bh) * (shape[1] // bw)
    blocks = jnp.zeros((nb, bh, bw), jnp.float32).at[idx].add(vals)
    return _unblockify(blocks, shape, cfg.block_shape)


def compressed_allreduce(g, err, cfg: CompressionConfig, axis_names):
    """Inside shard_map: mean-reduce ``g`` over ``axis_names`` at reduced
    traffic. Returns (reduced_grad, new_error)."""
    vals, idx, new_err = compress(g, err, cfg)
    gv = jax.lax.all_gather(vals, axis_names, tiled=False)   # (P, K, bh, bw)
    gi = jax.lax.all_gather(idx, axis_names, tiled=False)    # (P, K)
    n_peers = gv.shape[0]
    summed = decompress(gv.reshape(-1, *vals.shape[1:]),
                        gi.reshape(-1), g.shape, cfg)
    return (summed / n_peers).astype(g.dtype), new_err


def make_compressed_sync(mesh, axis_names, cfg: CompressionConfig):
    """Build a shard_map'd (grad, err) -> (mean_grad, new_err) sync for one
    2-D tensor. check_vma=False: gradients are device-VARYING despite the
    replicated-shape specs (classic DP semantics)."""
    from functools import partial
    from jax.sharding import PartitionSpec as P

    from repro.runtime.compat import shard_map

    @partial(shard_map, mesh=mesh, in_specs=(P(), P()),
             out_specs=(P(), P()), check_vma=False)
    def sync(g, e):
        return compressed_allreduce(g, e, cfg, axis_names)

    return sync


def init_error_buffers(params, cfg: CompressionConfig):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32) if compressible(p, cfg)
        else jnp.zeros((1,), jnp.float32), params)
