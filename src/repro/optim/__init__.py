from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, lr_at
from repro.optim.compression import (CompressionConfig, compress,
                                     compressed_allreduce, decompress,
                                     init_error_buffers)
