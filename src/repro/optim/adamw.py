"""AdamW + schedules + global-norm clipping + group-lasso proximal step.

flax/optax-free: optimizer state is a plain pytree {m, v, step} that shards
and checkpoints exactly like params. The proximal step (blockwise soft
threshold, core.regularizer.group_prox) realizes the paper's Eq. 1 group-ℓ1
term exactly rather than through a subgradient.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.regularizer import group_prox
from repro.core.sparsity import SparsityConfig


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"    # bf16 halves optimizer HBM at scale

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.state_dtype == "bfloat16" else jnp.float32


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.peak_lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.jdtype)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 sparsity: Optional[SparsityConfig] = None):
    """One AdamW step (+ optional group-lasso prox on targeted 2-D weights).

    Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree_util.tree_map(
        lambda mm, g: (b1 * mm.astype(jnp.float32) + (1 - b1) * g)
        .astype(cfg.jdtype), state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda vv, g: (b2 * vv.astype(jnp.float32) + (1 - b2) * g * g)
        .astype(cfg.jdtype), state["v"], grads)
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    lr = lr_at(cfg, step)

    def upd(path, p, mm, vv):
        mhat = mm.astype(jnp.float32) / c1
        vhat = vv.astype(jnp.float32) / c2
        newp = (p.astype(jnp.float32)
                - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * p.astype(jnp.float32)))
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if (sparsity is not None and sparsity.lambda_reg > 0
                and sparsity.applies_to(name) and newp.ndim in (2, 3)):
            bh, bw = sparsity.block_shape
            if newp.shape[-2] % bh == 0 and newp.shape[-1] % bw == 0:
                t = lr * sparsity.lambda_reg
                if newp.ndim == 3:   # scan-stacked layers
                    newp = jax.vmap(lambda l: group_prox(
                        l, sparsity.block_shape, t))(newp)
                else:
                    newp = group_prox(newp, sparsity.block_shape, t)
        return newp.astype(p.dtype)

    new_params = jax.tree_util.tree_map_with_path(upd, params, m, v)
    new_state = {"m": m, "v": v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
