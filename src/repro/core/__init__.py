"""Core sparsity library: the paper's contribution as composable JAX modules."""
from repro.core.bsr import (BSR, bsr_from_mask, bsr_to_dense, dense_to_bsr,
                            pattern_fingerprint, row_ids_from_indptr)
from repro.core.pattern_reuse import (PatternRegistry, ReuseStats,
                                      count_unique_intrablock_patterns,
                                      pattern_similarity)
from repro.core.pruner import (apply_masks, cubic_sparsity, init_masks,
                               oneshot_prune, sparsity_report, tie_group,
                               tied_prune, update_masks)
from repro.core.regularizer import (group_penalty, group_prox, l1_prox,
                                    tree_group_penalty)
from repro.core.sparsity import (SparsityConfig, actual_sparsity,
                                 apply_block_mask, block_norms,
                                 expand_block_mask, prune_to_sparsity,
                                 topk_block_mask)

__all__ = [n for n in dir() if not n.startswith("_")]
