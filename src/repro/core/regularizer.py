"""Group-ℓp regularization (paper Eq. 1-3) and its proximal operator.

The paper minimizes  f(w) + λ‖w‖_p  with the norm taken block-group-wise
(Eq. 3). We provide:

  * ``group_penalty``  -- Σ_blocks ‖w_block‖_p   (p ∈ {1, 2}; p=2 is the
    classic group lasso that drives *whole blocks* to zero, p=1 degenerates to
    elementwise lasso = the paper's "irregular sparsity" control arm)
  * ``group_prox``     -- blockwise soft-threshold (prox of λ·Σ‖·‖_2), used as
    a proximal step after the gradient update (ISTA-style), which is the
    numerically robust way to realize Eq. 2's constraint form.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.sparsity import block_norms


def group_penalty(w: jax.Array, block_shape: Tuple[int, int],
                  p: int = 2) -> jax.Array:
    """Σ_b ‖w_b‖_p over the block partition of a 2-D weight."""
    if p == 1:
        return jnp.sum(jnp.abs(w))  # block partition is irrelevant for ℓ1
    if p == 2:
        return jnp.sum(block_norms(w, block_shape, ord=2))
    raise ValueError(f"p={p} not supported")


def tree_group_penalty(params, block_shape: Tuple[int, int], p: int,
                       applies) -> jax.Array:
    """Sum ``group_penalty`` over every 2-D leaf whose path satisfies ``applies``."""
    total = jnp.zeros((), jnp.float32)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if leaf.ndim in (2, 3) and applies(name):
            bh, bw = block_shape
            if leaf.shape[-2] % bh == 0 and leaf.shape[-1] % bw == 0:
                w2 = leaf.astype(jnp.float32)
                if leaf.ndim == 3:   # scan-stacked: sum per-layer penalties
                    total = total + jnp.sum(jax.vmap(
                        lambda l: group_penalty(l, block_shape, p))(w2))
                else:
                    total = total + group_penalty(w2, block_shape, p)
    return total


def group_prox(w: jax.Array, block_shape: Tuple[int, int],
               thresh: float) -> jax.Array:
    """Blockwise soft-thresholding: shrink each block's norm by ``thresh``.

    prox_{t·Σ‖·‖2}(w)_b = w_b * max(0, 1 - t / ‖w_b‖2). Exactly zeroes blocks
    whose norm falls below ``thresh`` -- the mechanism by which group lasso
    produces BSR-exploitable structure.
    """
    bh, bw = block_shape
    norms = block_norms(w, block_shape, ord=2)
    scale = jnp.maximum(0.0, 1.0 - thresh / jnp.maximum(norms, 1e-30))
    scale = jnp.repeat(jnp.repeat(scale, bh, axis=0), bw, axis=1)
    return w * scale.astype(w.dtype)


def l1_prox(w: jax.Array, thresh: float) -> jax.Array:
    """Elementwise soft threshold (irregular-sparsity control arm)."""
    return jnp.sign(w) * jnp.maximum(jnp.abs(w) - thresh, 0.0)
