"""Block-structured sparsity config and mask algebra (paper §2.1).

A ``SparsityConfig`` describes how a 2-D weight is partitioned into B blocks
(Eq. 3) and what fraction of blocks must go to zero. Masks are computed at
block granularity from block norms (magnitude criterion) -- the ℓ0-style
projection used alongside the group-ℓ1 regularizer of core/regularizer.py.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Structured-sparsity settings for a family of weight matrices."""

    block_shape: Tuple[int, int] = (32, 1)   # paper's end-to-end CPU optimum
    sparsity: float = 0.8                    # fraction of blocks zeroed
    targets: Sequence[str] = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")
    group_norm_ord: int = 2                  # norm used to score a block
    lambda_reg: float = 0.0                  # group-lasso strength (0 = off)
    start_step: int = 0                      # gradual pruning window
    end_step: int = 1
    enabled: bool = True

    def applies_to(self, path: str) -> bool:
        return self.enabled and any(t in path for t in self.targets)


def block_norms(w: jax.Array, block_shape: Tuple[int, int],
                ord: int = 2) -> jax.Array:
    """(n_brows, n_bcols) per-block norms of a 2-D weight."""
    bh, bw = block_shape
    r, c = w.shape
    assert r % bh == 0 and c % bw == 0, (w.shape, block_shape)
    blocks = w.reshape(r // bh, bh, c // bw, bw)
    if ord == 1:
        return jnp.sum(jnp.abs(blocks), axis=(1, 3))
    if ord == 2:
        return jnp.sqrt(jnp.sum(blocks * blocks, axis=(1, 3)))
    raise ValueError(f"unsupported block norm ord={ord}")


def topk_block_mask(w: jax.Array, block_shape: Tuple[int, int],
                    sparsity: float, ord: int = 2) -> jax.Array:
    """Keep the top-(1-sparsity) fraction of blocks by norm. Bool block mask.

    Deterministic under jit (static k); ties broken by flat index order.
    """
    norms = block_norms(w, block_shape, ord)
    n_blocks = norms.size
    k_keep = max(1, int(round((1.0 - sparsity) * n_blocks)))
    flat = norms.reshape(-1)
    # threshold = k-th largest value; keep strictly-above plus enough ties
    _, keep_idx = jax.lax.top_k(flat, k_keep)
    mask = jnp.zeros((n_blocks,), bool).at[keep_idx].set(True)
    return mask.reshape(norms.shape)


def expand_block_mask(mask: jax.Array, block_shape: Tuple[int, int]) -> jax.Array:
    """Block mask (n_brows, n_bcols) -> elementwise {0,1} mask."""
    bh, bw = block_shape
    return jnp.repeat(jnp.repeat(mask, bh, axis=0), bw, axis=1)


def apply_block_mask(w: jax.Array, mask: jax.Array,
                     block_shape: Tuple[int, int]) -> jax.Array:
    return w * expand_block_mask(mask, block_shape).astype(w.dtype)


def prune_to_sparsity(w: jax.Array, block_shape: Tuple[int, int],
                      sparsity: float, ord: int = 2) -> Tuple[jax.Array, jax.Array]:
    """One-shot block-magnitude pruning. Returns (pruned_w, block_mask)."""
    mask = topk_block_mask(w, block_shape, sparsity, ord)
    return apply_block_mask(w, mask, block_shape), mask


def actual_sparsity(w: jax.Array, block_shape: Tuple[int, int]) -> jax.Array:
    """Fraction of all-zero blocks in ``w``."""
    norms = block_norms(w, block_shape, ord=1)
    return jnp.mean((norms == 0).astype(jnp.float32))


def pad_to_blocks(w: jax.Array, block_shape: Tuple[int, int]) -> jax.Array:
    """Zero-pad trailing dims so both divide the block shape (for odd vocab etc.)."""
    bh, bw = block_shape
    r, c = w.shape
    pr = (-r) % bh
    pc = (-c) % bw
    if pr == 0 and pc == 0:
        return w
    return jnp.pad(w, ((0, pr), (0, pc)))
