"""Block Sparse Row (BSR) representation, SciPy-compatible, as a JAX pytree.

The paper represents sparse weights as ``data / indices / indptr`` (SciPy BSR)
inside TVM. We keep the identical layout so tests can cross-check against
``scipy.sparse.bsr_matrix``, but make it a static-shape pytree so it can flow
through ``jax.jit`` / ``pjit``:

  * ``data``    -- (nnzb, bh, bw) nonzero block values (zero-padded to a static
                   block count so recompilation is never pattern-dependent)
  * ``indices`` -- (nnzb,) int32 block-column index of each stored block
  * ``indptr``  -- (n_block_rows + 1,) int32, CSR-style row pointers

Padding blocks carry ``data == 0`` and live in the *last* block row (keeping
row-major sortedness), so every consumer -- reference einsum, gather path and
the Pallas kernel -- is numerically unaffected by padding.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BSR:
    """A 2-D block-sparse matrix of logical shape ``shape``."""

    data: jax.Array      # (nnzb, bh, bw)
    indices: jax.Array   # (nnzb,) int32
    indptr: jax.Array    # (n_brows + 1,) int32
    shape: Tuple[int, int]        # static
    block_shape: Tuple[int, int]  # static

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.indices, self.indptr), (self.shape, self.block_shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, indices, indptr = children
        shape, block_shape = aux
        return cls(data, indices, indptr, shape, block_shape)

    # -- derived static properties ------------------------------------------
    @property
    def nnzb(self) -> int:
        return self.data.shape[0]

    @property
    def n_brows(self) -> int:
        return self.shape[0] // self.block_shape[0]

    @property
    def n_bcols(self) -> int:
        return self.shape[1] // self.block_shape[1]

    @property
    def density(self) -> float:
        return self.nnzb / max(1, self.n_brows * self.n_bcols)

    @property
    def dtype(self):
        return self.data.dtype

    def block_row_ids(self) -> jax.Array:
        """(nnzb,) block-row id of every stored block (inverse of indptr)."""
        return row_ids_from_indptr(self.indptr, self.nnzb)

    def astype(self, dtype) -> "BSR":
        return BSR(self.data.astype(dtype), self.indices, self.indptr,
                   self.shape, self.block_shape)


def row_ids_from_indptr(indptr: jax.Array, nnzb: int) -> jax.Array:
    """CSR indptr -> per-entry row ids, statically shaped, jit-safe."""
    # row_ids[j] = #{r : indptr[r+1] <= j}
    j = jnp.arange(nnzb)
    return jnp.sum(j[:, None] >= indptr[None, 1:], axis=1).astype(jnp.int32)


def _block_view(dense: np.ndarray, bh: int, bw: int) -> np.ndarray:
    r, c = dense.shape
    assert r % bh == 0 and c % bw == 0, (dense.shape, (bh, bw))
    return dense.reshape(r // bh, bh, c // bw, bw).transpose(0, 2, 1, 3)


def block_mask(dense: np.ndarray, block_shape: Tuple[int, int]) -> np.ndarray:
    """(n_brows, n_bcols) bool mask of blocks containing any nonzero."""
    blocks = _block_view(np.asarray(dense), *block_shape)
    return np.any(blocks != 0, axis=(2, 3))


def dense_to_bsr(dense, block_shape: Tuple[int, int], nnzb: int | None = None,
                 dtype=None) -> BSR:
    """Convert a dense matrix to BSR, padding the block list to ``nnzb``.

    Runs on host (numpy): pattern extraction is a data-dependent-shape
    operation and belongs outside jit, exactly as TVM performs the BSR
    conversion at compile/packing time rather than at inference time.
    """
    dense = np.asarray(dense)
    bh, bw = block_shape
    mask = block_mask(dense, block_shape)
    rows, cols = np.nonzero(mask)  # row-major sorted: rows ascending
    real = len(rows)
    if nnzb is None:
        nnzb = max(real, 1)
    if real > nnzb:
        raise ValueError(f"nnzb={nnzb} < actual nonzero blocks {real}")

    blocks = _block_view(dense, bh, bw)[rows, cols]  # (real, bh, bw)
    n_brows = dense.shape[0] // bh

    data = np.zeros((nnzb, bh, bw), dtype=dense.dtype)
    data[:real] = blocks
    indices = np.zeros((nnzb,), dtype=np.int32)
    indices[:real] = cols
    # padding blocks live in the last row, column 0, with zero data
    counts = np.bincount(rows, minlength=n_brows)
    counts[-1] += nnzb - real
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    out_dtype = dtype or dense.dtype
    if out_dtype == np.float64:  # jax default x64-off
        out_dtype = np.float32
    return BSR(jnp.asarray(data, dtype=out_dtype), jnp.asarray(indices),
               jnp.asarray(indptr), tuple(dense.shape), (bh, bw))


def bsr_to_dense(m: BSR) -> jax.Array:
    """Densify (jit-safe; used by the reference oracle)."""
    bh, bw = m.block_shape
    rows = m.block_row_ids()
    flat_idx = rows * m.n_bcols + m.indices  # (nnzb,)
    blocks = jnp.zeros((m.n_brows * m.n_bcols, bh, bw), m.data.dtype)
    # padding blocks are zero-valued, .add keeps them harmless even if they
    # collide with a real block slot
    blocks = blocks.at[flat_idx].add(m.data)
    return (blocks.reshape(m.n_brows, m.n_bcols, bh, bw)
            .transpose(0, 2, 1, 3).reshape(m.shape))


def bsr_from_mask(dense, mask: np.ndarray, block_shape: Tuple[int, int],
                  nnzb: int | None = None) -> BSR:
    """Build BSR keeping only blocks where ``mask`` (n_brows, n_bcols) is set."""
    dense = np.asarray(dense)
    bh, bw = block_shape
    keep = np.kron(mask, np.ones((bh, bw), dtype=bool))
    return dense_to_bsr(np.where(keep, dense, 0), block_shape, nnzb=nnzb)


def pattern_fingerprint(m: BSR) -> bytes:
    """Hashable fingerprint of the sparsity *structure* (not values).

    This is the task-identity key in the TVM-task-scheduler analogue
    (core/pattern_reuse.py): two layers whose BSR structure matches can reuse
    one compiled executable.
    """
    idx = np.asarray(jax.device_get(m.indices), dtype=np.int32)
    ptr = np.asarray(jax.device_get(m.indptr), dtype=np.int32)
    header = np.array([*m.shape, *m.block_shape, m.nnzb], dtype=np.int64)
    return header.tobytes() + ptr.tobytes() + idx.tobytes()
