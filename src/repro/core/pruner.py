"""Gradual block-magnitude pruning schedule + mask state.

Training-time driver of the paper's §2.1: ramps block sparsity from 0 to the
target with the standard cubic schedule, recomputing block masks from current
magnitudes and re-applying them every step (masked weights stay dead).

State is a pytree of block masks parallel to the (2-D, targeted) params, so it
checkpoints/reshards exactly like params do.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.sparsity import (SparsityConfig, apply_block_mask,
                                 expand_block_mask, topk_block_mask)


def _path_name(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


def _prunable(cfg: SparsityConfig, name: str, leaf) -> bool:
    """2-D weights and scan-stacked (L, out, in) 3-D weights are prunable."""
    if leaf.ndim not in (2, 3) or not cfg.applies_to(name):
        return False
    bh, bw = cfg.block_shape
    return leaf.shape[-2] % bh == 0 and leaf.shape[-1] % bw == 0


def cubic_sparsity(step, cfg: SparsityConfig):
    """Zhu & Gupta cubic ramp: s(t) = s_f * (1 - (1 - t_norm)^3), clipped."""
    span = max(1, cfg.end_step - cfg.start_step)
    t = jnp.clip((step - cfg.start_step) / span, 0.0, 1.0)
    return cfg.sparsity * (1.0 - (1.0 - t) ** 3)


def _vmap2d(fn, leaf, *rest):
    """Apply a 2-D-weight function to a 2-D or stacked 3-D leaf."""
    if leaf.ndim == 2:
        return fn(leaf, *rest)
    return jax.vmap(lambda l, *r: fn(l, *r))(leaf, *rest)


def init_masks(params, cfg: SparsityConfig) -> Dict:
    """All-ones block masks for every prunable leaf; None elsewhere.
    Stacked leaves get per-layer masks (L, nbr, nbc)."""
    def one(path, leaf):
        name = _path_name(path)
        if _prunable(cfg, name, leaf):
            bh, bw = cfg.block_shape
            shape = leaf.shape[:-2] + (leaf.shape[-2] // bh,
                                       leaf.shape[-1] // bw)
            return jnp.ones(shape, bool)
        return None
    return jax.tree_util.tree_map_with_path(one, params)


def update_masks(params, masks, step, cfg: SparsityConfig):
    """Recompute block masks at the scheduled sparsity for this step."""
    target = cubic_sparsity(step, cfg)

    def upd_2d(leaf):
        # topk needs a static k: evaluate schedule on host is not possible
        # under jit, so we threshold block norms against the target quantile.
        from repro.core.sparsity import block_norms
        norms = block_norms(leaf.astype(jnp.float32), cfg.block_shape,
                            cfg.group_norm_ord)
        thresh = jnp.quantile(norms.reshape(-1), target)
        return norms > thresh

    def upd(path, leaf, mask):
        if mask is None:
            return None
        return _vmap2d(upd_2d, leaf)

    return jax.tree_util.tree_map_with_path(
        upd, params, masks, is_leaf=lambda x: x is None)


def apply_masks(params, masks, cfg: SparsityConfig):
    """Zero out masked blocks (keeps pruned weights dead after optimizer step)."""
    def app(leaf, mask):
        if mask is None:
            return leaf
        return _vmap2d(lambda l, m: apply_block_mask(l, m, cfg.block_shape),
                       leaf, mask)
    return jax.tree_util.tree_map(
        app, params, masks, is_leaf=lambda x: x is None)


def oneshot_prune(params, cfg: SparsityConfig):
    """One-shot prune to the target ratio. Returns (params, masks)."""
    def pr(path, leaf):
        name = _path_name(path)
        if _prunable(cfg, name, leaf):
            def p2(l):
                mask = topk_block_mask(l.astype(jnp.float32), cfg.block_shape,
                                       cfg.sparsity, cfg.group_norm_ord)
                return apply_block_mask(l, mask, cfg.block_shape), mask
            if leaf.ndim == 2:
                return p2(leaf)
            return jax.vmap(p2)(leaf)
        return leaf, None

    pruned = jax.tree_util.tree_map_with_path(lambda p, l: pr(p, l)[0], params)
    masks = jax.tree_util.tree_map_with_path(lambda p, l: pr(p, l)[1], params)
    return pruned, masks


def tie_group(name: str) -> str:
    """Tie key of a param path: layer indices are wildcarded so all layers of
    a stack score against one shared mask ('layers/[3]/attn/wq/w' and
    'layers/[7]/attn/wq/w' -> 'layers/*/attn/wq/w'; tuple indices render as
    '[i]', dict keys that are digits as 'i')."""
    return "/".join("*" if tok.strip("[]").isdigit() else tok
                    for tok in name.split("/"))


def tied_prune(params, cfg: SparsityConfig):
    """One-shot prune with ONE block mask shared across all layers of each
    projection group. Returns (params, masks) like :func:`oneshot_prune`.

    Block scores are the mean block norm across the group's members (and, for
    scan-stacked 3-D leaves, across the leading layer axis). This is the
    serving-side stand-in for the high inter-layer pattern overlap that the
    paper's small-block regularized training yields (§2.2): with tied masks
    the cross-layer union pack of ``repro.serving`` adds zero padding
    (``union_overhead`` = 1.0). Members whose shape differs from the rest of
    their group fall back to an independent mask.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    names = [_path_name(path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]

    # group prunable leaves by wildcarded path (same 2-D shape required)
    groups: Dict[str, list] = {}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        if _prunable(cfg, name, leaf):
            groups.setdefault(tie_group(name), []).append(i)
    for key in list(groups):
        shapes = {leaves[i].shape[-2:] for i in groups[key]}
        if len(shapes) > 1:      # heterogeneous group: untie its members
            for i in groups.pop(key):
                groups[names[i]] = [i]

    from repro.core.sparsity import block_norms

    def member_norms(leaf):
        """(nbr, nbc) block scores; stacked 3-D leaves mean over layers."""
        n = _vmap2d(lambda l: block_norms(l.astype(jnp.float32),
                                          cfg.block_shape,
                                          cfg.group_norm_ord), leaf)
        return n if leaf.ndim == 2 else jnp.mean(n, axis=0)

    new_leaves = list(leaves)
    mask_leaves = [None] * len(leaves)
    for idxs in groups.values():
        norms = jnp.mean(jnp.stack([member_norms(leaves[i]) for i in idxs]),
                         axis=0)
        keep = max(1, int(round(norms.size * (1.0 - cfg.sparsity))))
        _, keep_idx = jax.lax.top_k(norms.reshape(-1), keep)
        mask = jnp.zeros((norms.size,), bool).at[keep_idx].set(True)
        mask = mask.reshape(norms.shape)
        expand = expand_block_mask(mask, cfg.block_shape).astype(jnp.float32)
        for i in idxs:
            leaf = leaves[i]
            new_leaves[i] = (leaf.astype(jnp.float32) * expand).astype(
                leaf.dtype)
            mask_leaves[i] = (mask if leaf.ndim == 2 else jnp.broadcast_to(
                mask, leaf.shape[:-2] + mask.shape))
    pruned = jax.tree_util.tree_unflatten(treedef, new_leaves)
    masks = jax.tree_util.tree_unflatten(treedef, mask_leaves)
    return pruned, masks


def sparsity_report(params, cfg: SparsityConfig) -> Dict[str, float]:
    """Per-target actual block sparsity (for logging / EXPERIMENTS.md)."""
    from repro.core.sparsity import actual_sparsity
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = _path_name(path)
        if _prunable(cfg, name, leaf):
            s = _vmap2d(lambda l: actual_sparsity(l, cfg.block_shape), leaf)
            out[name] = float(jnp.mean(s))
    return out
