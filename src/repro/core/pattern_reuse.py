"""Pattern-reuse cache: the TVM-task-scheduler analogue (paper §2.2, bullet 3).

TVM stores each BSR op + its indices/indptr as a *task*; identical tasks are
compiled once and reused, similar tasks are scheduled adjacently. In the
JAX/XLA world the equivalent leverage is **pattern specialization**: when the
sparsity structure (indices/indptr) is baked into the computation as
constants, XLA can constant-fold the gather schedule -- but each distinct
pattern then needs its own executable. This module provides the task buffer:

  * ``PatternRegistry.specialize(fn, bsr)`` returns a compiled callable where
    the BSR *structure* is static and only ``data`` (values) is a runtime
    argument. Executables are cached by ``pattern_fingerprint`` -- two layers
    with identical structure share one compilation (a cache *hit*, TVM's
    "identical tasks are reused").
  * hit/miss counters quantify reuse, the instrumentation the paper lists as
    follow-up work ("tools for introspection of task reuse by the scheduler").

Small sparsity blocks => fewer distinct patterns => more hits, which is
exactly the paper's explanation for the 1x32-beats-1x384 non-monotonicity.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Hashable

import jax
import numpy as np

from repro.core.bsr import BSR, pattern_fingerprint


@dataclasses.dataclass
class ReuseStats:
    hits: int = 0
    misses: int = 0

    @property
    def total(self) -> int:
        return self.hits + self.misses

    @property
    def reuse_rate(self) -> float:
        return self.hits / self.total if self.total else 0.0


class PatternRegistry:
    """Task buffer mapping sparsity structure -> compiled executable.

    Besides ``specialize`` (BSR -> jitted callable), the registry exposes a
    generic ``cached(key, builder)`` so other pattern-keyed artifacts -- in
    particular the precomputed ``RowPackPlan`` execution plans of
    kernels/exec_plan.py -- share the same task buffer and the same hit/miss
    instrumentation. One registry therefore answers the paper's introspection
    question ("how often does the scheduler reuse a task?") for every
    specialization kind at once.
    """

    def __init__(self):
        self._cache: Dict[Hashable, Any] = {}
        # reentrant: a builder may itself consult the registry (e.g. a fused
        # plan built from per-projection plans). Held across the build so
        # concurrent engine admissions (serving/engine.py) cannot race plan
        # construction -- each key is built exactly once and the hit/miss
        # counters stay exact under threading.
        self._lock = threading.RLock()
        self.stats = ReuseStats()

    def cached(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        """Generic task lookup: return the cached artifact for ``key``,
        building (a *miss*, TVM's "new task -> compile") only on first use.
        Thread-safe: lookup, build, and insert happen under one lock."""
        with self._lock:
            if key in self._cache:
                self.stats.hits += 1
                return self._cache[key]
            self.stats.misses += 1
            value = builder()
            self._cache[key] = value
            return value

    def peek(self, key: Hashable) -> bool:
        """True when ``key`` is already built (no counter update, no build)
        -- lets callers attribute the upcoming ``cached`` call to their own
        accounting scope (e.g. per-shard hit/miss in sharded export)."""
        with self._lock:
            return key in self._cache

    def specialize(self, fn: Callable, bsr: BSR) -> Callable:
        """Return ``lambda data, *args: fn(bsr_with(data), *args)`` compiled
        with the pattern held static. Cached by (fn identity, pattern)."""
        indices, indptr = bsr.indices, bsr.indptr
        shape, block_shape = bsr.shape, bsr.block_shape

        def build():
            @jax.jit
            def specialized(data, *args):
                m = BSR(data, indices, indptr, shape, block_shape)
                return fn(m, *args)
            return specialized

        return self.cached((id(fn), pattern_fingerprint(bsr)), build)

    def n_unique_patterns(self) -> int:
        return len(self._cache)


def pattern_similarity(a: BSR, b: BSR) -> float:
    """Jaccard similarity of two block patterns (TVM schedules 'similar'
    tasks adjacently; we expose the metric for scheduling instrumentation)."""
    if a.shape != b.shape or a.block_shape != b.block_shape:
        return 0.0
    def occupied(m: BSR):
        rows = np.asarray(jax.device_get(m.block_row_ids()))
        cols = np.asarray(jax.device_get(m.indices))
        data = np.asarray(jax.device_get(m.data))
        nz = np.any(data != 0, axis=(1, 2))
        return set(zip(rows[nz].tolist(), cols[nz].tolist()))
    sa, sb = occupied(a), occupied(b)
    if not sa and not sb:
        return 1.0
    return len(sa & sb) / len(sa | sb)


def count_unique_intrablock_patterns(w, block_shape) -> int:
    """Number of distinct intra-block zero patterns across a weight matrix.

    Paper §4: small blocks keep this cardinality low, enabling reuse; it
    explodes for large blocks. Used by benchmarks/fig2 to show the mechanism.
    """
    w = np.asarray(jax.device_get(w))
    bh, bw = block_shape
    r, c = w.shape
    blocks = (w.reshape(r // bh, bh, c // bw, bw)
              .transpose(0, 2, 1, 3).reshape(-1, bh * bw))
    patt = (blocks != 0)
    return len({p.tobytes() for p in patt})
